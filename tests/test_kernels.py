"""Per-kernel validation: Pallas (interpret=True) and blocked-jnp paths vs the
pure-jnp oracles in ``repro.kernels.ref``, swept over shapes/dtypes, plus
custom-vjp gradient checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.ssm_scan import ssm_scan_pallas

KEY = jax.random.PRNGKey(42)


def _qkv(B, S, H, Hkv, D, dtype=jnp.float32, Sk=None):
    ks = jax.random.split(KEY, 3)
    Sk = Sk or S
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, Sk, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, Sk, Hkv, D), dtype)
    return q, k, v


# ---------------------------------------------------------------- rmsnorm
@pytest.mark.parametrize("shape", [(4, 8, 128), (2, 256), (3, 5, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_pallas_matches_ref(shape, dtype):
    x = jax.random.normal(KEY, shape, dtype)
    g = (jax.random.normal(jax.random.PRNGKey(1), shape[-1:]) * 0.1).astype(dtype)
    want = ref.rmsnorm(x, g)
    got = rmsnorm_pallas(x, g, interpret=True, block_rows=16)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("d", [64, 128, 384])
def test_fused_rmsnorm_matches_ref(d):
    """The ``--fused-rmsnorm`` hot-path entry: ``ops.rmsnorm(fused=True)``
    must route to the Pallas kernel (interpret mode on CPU) for ANY feature
    dim — including the unaligned d=64 smoke config the %128 tile gate would
    otherwise send to the reference norm."""
    from repro.kernels import ops

    x = jax.random.normal(KEY, (2, 8, d), jnp.float32)
    g = (jax.random.normal(jax.random.PRNGKey(1), (d,)) * 0.1).astype(jnp.float32)
    want = ref.rmsnorm(x, g)
    got = ops.rmsnorm(x, g, fused=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-6, rtol=1e-6)


def test_fused_rmsnorm_grads_match_ref():
    """The fused norm's custom VJP (reference backward) must reproduce the
    reference norm's gradients — so a fused train step stays a faithful
    optimization, not a different model."""
    from repro.kernels import ops

    x = jax.random.normal(KEY, (4, 96), jnp.float32)
    g = (jax.random.normal(jax.random.PRNGKey(1), (96,)) * 0.1).astype(jnp.float32)

    def loss_ref(x, g):
        return jnp.sum(jnp.sin(ref.rmsnorm(x, g)))

    def loss_fused(x, g):
        return jnp.sum(jnp.sin(ops.rmsnorm(x, g, fused=True)))

    want = jax.grad(loss_ref, argnums=(0, 1))(x, g)
    got = jax.grad(loss_fused, argnums=(0, 1))(x, g)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6, rtol=1e-6)


def test_fused_rmsnorm_train_step_matches_ref_norm():
    """End to end through the population train step: a ``fused_rmsnorm``
    model must train within bit-tolerance of the reference-norm model (the
    forward kernel is allclose, the backward is the reference VJP)."""
    import dataclasses

    from repro.configs import get_smoke_config
    from repro.configs.base import TrainConfig
    from repro.data.pipeline import SyntheticLM, synth_batch
    from repro.optim.hparams import hparams_from_dict, stack_hparams
    from repro.train.population import (
        init_population_state, make_population_train_step)

    losses = {}
    for fused in (False, True):
        cfg = dataclasses.replace(get_smoke_config("starcoder2-3b"),
                                  fused_rmsnorm=fused)
        tc = TrainConfig(model=cfg, total_steps=8)
        data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16,
                           global_batch=2)
        pstate = init_population_state(jax.random.PRNGKey(0), tc, 2)
        hp = stack_hparams([hparams_from_dict(
            {"learning_rate": 1e-3, "n_iterations": 8}, tc)] * 2)
        step = jax.jit(make_population_train_step(tc))
        for s in range(3):
            pstate, metrics = step(pstate, synth_batch(data, 0, s), hp)
        losses[fused] = np.asarray(metrics["loss"], np.float32)
    np.testing.assert_allclose(losses[True], losses[False],
                               atol=5e-5, rtol=1e-5)


# ------------------------------------------------- fused attention (ops seam)
def test_fused_attention_matches_ref():
    """The ``--fused-attention`` hot-path entry: ``ops.attention(fused=True)``
    must route to the Pallas flash kernel (interpret mode on CPU) even at the
    short, unaligned smoke seq lengths (the kernel pads internally)."""
    from repro.kernels import ops

    q, k, v = _qkv(2, 16, 4, 2, 16)
    want = ref.attention(q, k, v, causal=True)
    got = ops.attention(q, k, v, causal=True, fused=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


def test_fused_attention_decode_falls_back_to_ref():
    """Cached decode (kv_len/q_offset) stays on the reference op: the flash
    kernel only covers the full-sequence training forward."""
    from repro.kernels import ops

    q, k, v = _qkv(1, 1, 4, 2, 16, Sk=32)
    want = ops.attention(q, k, v, causal=True, q_offset=7,
                         kv_len=jnp.asarray(8))
    got = ops.attention(q, k, v, causal=True, q_offset=7,
                        kv_len=jnp.asarray(8), fused=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0)


def test_fused_attention_grads_match_ref():
    from repro.kernels import ops

    q, k, v = _qkv(1, 32, 2, 2, 16)

    def loss_ref(q, k, v):
        return (ref.attention(q, k, v, causal=True) ** 2).sum()

    def loss_fused(q, k, v):
        return (ops.attention(q, k, v, causal=True, fused=True) ** 2).sum()

    want = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    got = jax.grad(loss_fused, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=5e-5)


def _population_losses(arch, steps=3, **cfg_overrides):
    """Final per-lane losses of a short 2-lane population flight — the
    end-to-end parity harness for the fused-kernel flags."""
    import dataclasses

    from repro.configs import get_smoke_config
    from repro.configs.base import TrainConfig
    from repro.data.pipeline import SyntheticLM, synth_batch
    from repro.optim.hparams import hparams_from_dict, stack_hparams
    from repro.train.population import (
        init_population_state, make_population_train_step)

    cfg = dataclasses.replace(get_smoke_config(arch), **cfg_overrides)
    tc = TrainConfig(model=cfg, total_steps=8)
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16, global_batch=2)
    pstate = init_population_state(jax.random.PRNGKey(0), tc, 2)
    hp = stack_hparams([hparams_from_dict(
        {"learning_rate": 1e-3, "n_iterations": 8}, tc)] * 2)
    step = jax.jit(make_population_train_step(tc))
    for s in range(steps):
        pstate, metrics = step(pstate, synth_batch(data, 0, s), hp)
    return np.asarray(metrics["loss"], np.float32)


def test_fused_attention_train_step_matches_ref():
    """End to end through the population train step: a ``fused_attention``
    model must train within tolerance of the reference-attention model (the
    flash forward reassociates the softmax reductions, so the bound is looser
    than rmsnorm's but still tight after 3 optimizer steps)."""
    want = _population_losses("starcoder2-3b")
    got = _population_losses("starcoder2-3b", fused_attention=True)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------- attention
CASES = [
    dict(B=2, S=128, H=4, Hkv=2, D=32, causal=True, window=None, softcap=None),
    dict(B=1, S=192, H=4, Hkv=4, D=64, causal=True, window=64, softcap=None),
    dict(B=1, S=160, H=8, Hkv=1, D=32, causal=True, window=None, softcap=30.0),
    dict(B=2, S=96, H=2, Hkv=2, D=16, causal=False, window=None, softcap=None),
    dict(B=1, S=200, H=6, Hkv=3, D=32, causal=True, window=96, softcap=50.0),
]


@pytest.mark.parametrize("case", CASES, ids=[f"case{i}" for i in range(len(CASES))])
def test_blocked_attention_matches_oracle(case):
    c = dict(case)
    q, k, v = _qkv(c.pop("B"), c.pop("S"), c.pop("H"), c.pop("Hkv"), c.pop("D"))
    want = ref.attention(q, k, v, **c)
    got = ref.attention_blocked(q, k, v, block_q=64, block_kv=48, **c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("case", CASES, ids=[f"case{i}" for i in range(len(CASES))])
def test_pallas_flash_matches_oracle(case):
    c = dict(case)
    q, k, v = _qkv(c.pop("B"), c.pop("S"), c.pop("H"), c.pop("Hkv"), c.pop("D"))
    want = ref.attention(q, k, v, **c)
    got = flash_attention(q, k, v, block_q=64, block_kv=64, interpret=True, **c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("case", CASES[:3], ids=["grad0", "grad1", "grad2"])
def test_flash_vjp_matches_oracle_grads(case):
    c = dict(case)
    q, k, v = _qkv(c.pop("B"), c.pop("S"), c.pop("H"), c.pop("Hkv"), c.pop("D"))

    def loss_ref(q, k, v):
        return (ref.attention(q, k, v, **c) ** 2).sum()

    def loss_blk(q, k, v):
        return (ref.attention_blocked(q, k, v, block_q=64, block_kv=48, **c) ** 2).sum()

    def loss_pal(q, k, v):
        return (flash_attention(q, k, v, block_q=64, block_kv=64, interpret=True, **c) ** 2).sum()

    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for loss in (loss_blk, loss_pal):
        gg = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(gr, gg):
            scale = float(jnp.abs(a).max()) + 1e-9
            np.testing.assert_allclose(np.asarray(b) / scale, np.asarray(a) / scale,
                                       atol=5e-5, rtol=5e-5)


def test_attention_bf16_path():
    q, k, v = _qkv(1, 128, 4, 2, 32, jnp.bfloat16)
    want = ref.attention(q, k, v, causal=True)
    got = flash_attention(q, k, v, causal=True, block_q=64, block_kv=64, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32),
                               atol=3e-2, rtol=3e-2)


def test_attention_decode_kv_len():
    """Decode path: q_offset + kv_len masking against a slice-equivalent."""
    q, k, v = _qkv(2, 1, 4, 2, 32, Sk=64)
    pos = 37
    want = ref.attention(q, k[:, : pos + 1], v[:, : pos + 1], causal=True, q_offset=pos)
    got = ref.attention(q, k, v, causal=True, q_offset=pos, kv_len=jnp.asarray(pos + 1))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


# ---------------------------------------------------------------- ssm scan
@pytest.mark.parametrize("B,L,D,N,chunk,block_d", [
    (2, 64, 32, 8, 16, 16),
    (1, 100, 64, 16, 32, 64),
    (3, 48, 128, 4, 48, 32),
])
def test_ssm_scan_pallas_matches_ref(B, L, D, N, chunk, block_d):
    ks = jax.random.split(KEY, 6)
    x = jax.random.normal(ks[0], (B, L, D)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, D)) - 1)
    A = -jnp.exp(jax.random.normal(ks[2], (D, N)) * 0.3)
    Bc = jax.random.normal(ks[3], (B, L, N)) * 0.5
    Cc = jax.random.normal(ks[4], (B, L, N)) * 0.5
    Dk = jax.random.normal(ks[5], (D,)) * 0.2
    y_want, h_want = ref.ssm_scan(x, dt, A, Bc, Cc, Dk, chunk=chunk)
    y_got, h_got = ssm_scan_pallas(x, dt, A, Bc, Cc, Dk, chunk=chunk,
                                   block_d=block_d, interpret=True)
    np.testing.assert_allclose(np.asarray(y_got), np.asarray(y_want), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h_got), np.asarray(h_want), atol=1e-4, rtol=1e-4)


def test_ssm_scan_equals_stepwise_decode():
    """Property: the chunked scan == token-by-token decode recurrence."""
    B, L, D, N = 2, 17, 8, 4
    ks = jax.random.split(KEY, 6)
    x = jax.random.normal(ks[0], (B, L, D)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, D)))
    A = -jnp.exp(jax.random.normal(ks[2], (D, N)) * 0.3)
    Bc = jax.random.normal(ks[3], (B, L, N))
    Cc = jax.random.normal(ks[4], (B, L, N))
    Dk = jax.random.normal(ks[5], (D,))
    y_scan, h_scan = ref.ssm_scan(x, dt, A, Bc, Cc, Dk, chunk=5)
    h = jnp.zeros((B, D, N))
    ys = []
    for t in range(L):
        y, h = ref.ssm_decode_step(x[:, t], dt[:, t], A, Bc[:, t], Cc[:, t], Dk, h)
        ys.append(y)
    y_step = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_step), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_scan), np.asarray(h), atol=1e-4)


# ------------------------------------------------- fused ssm scan (ops seam)
def _ssm_inputs(B=2, L=24, D=128, N=8):
    ks = jax.random.split(KEY, 6)
    x = jax.random.normal(ks[0], (B, L, D)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, D)) - 1)
    A = -jnp.exp(jax.random.normal(ks[2], (D, N)) * 0.3)
    Bc = jax.random.normal(ks[3], (B, L, N)) * 0.5
    Cc = jax.random.normal(ks[4], (B, L, N)) * 0.5
    Dk = jax.random.normal(ks[5], (D,)) * 0.2
    return x, dt, A, Bc, Cc, Dk


def test_fused_ssm_scan_matches_ref():
    """The ``--fused-ssm`` hot-path entry: ``ops.ssm_scan(fused=True)`` must
    route to the Pallas chunked kernel (interpret mode on CPU) at the smoke
    d_inner=128 (block_d = gcd(d, 512) keeps the tile divisibility)."""
    from repro.kernels import ops

    args = _ssm_inputs()
    y_want, h_want = ref.ssm_scan(*args, chunk=16)
    y_got, h_got = ops.ssm_scan(*args, chunk=16, fused=True)
    np.testing.assert_allclose(np.asarray(y_got), np.asarray(y_want),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h_got), np.asarray(h_want),
                               atol=1e-4, rtol=1e-4)


def test_fused_ssm_grads_match_ref():
    """The fused scan's custom VJP replays the reference backward, so the
    gradients must agree with differentiating the reference scan directly."""
    from repro.kernels import ops

    x, dt, A, Bc, Cc, Dk = _ssm_inputs(B=1, L=16, D=32, N=4)

    def loss_ref(x, dt, Bc):
        y, h = ref.ssm_scan(x, dt, A, Bc, Cc, Dk, chunk=8)
        return (y ** 2).sum() + (h ** 2).sum()

    def loss_fused(x, dt, Bc):
        y, h = ops.ssm_scan(x, dt, A, Bc, Cc, Dk, chunk=8, fused=True)
        return (y ** 2).sum() + (h ** 2).sum()

    want = jax.grad(loss_ref, argnums=(0, 1, 2))(x, dt, Bc)
    got = jax.grad(loss_fused, argnums=(0, 1, 2))(x, dt, Bc)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4, rtol=5e-4)


def test_fused_ssm_train_step_matches_ref():
    """End to end on the pure-SSM arch: a ``fused_ssm`` falcon-mamba model
    must train within tolerance of the reference-scan model."""
    want = _population_losses("falcon-mamba-7b")
    got = _population_losses("falcon-mamba-7b", fused_ssm=True)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)
