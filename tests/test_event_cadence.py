"""Event-cadence arithmetic for the streaming drivers.

The streaming engine only re-enters the host at *event* steps: the absolute
divergence/snapshot poll anchor, lane budget ends, rung boundaries (host
rules), or — with --device-rules — just the poll anchor and the whole-flight
drain.  These are pure integer helpers, so they get direct unit tests here
instead of riding only inside full flights; the off-by-one this pins down is
the chunk-boundary case where an event is due AT the current step (a freshly
leased zero-budget job, a poll anchor the loop just landed on): the helpers
must return ``s`` itself — never a step in the past — so the driver re-runs
the event pass instead of dispatching a negative-length (or no-op) chunk.
"""
import numpy as np
import pytest

from repro.core.resource.vectorized import QueueFeedScheduler
from repro.launch.hpo import (
    PopulationTrial,
    _device_dispatch_horizon,
    _next_event_step,
    _poll_anchor,
    _pow2_ceil,
    _pow2_floor,
)


# -- pow2 helpers -----------------------------------------------------------------


def test_pow2_floor_and_ceil():
    assert [_pow2_floor(n) for n in (1, 2, 3, 7, 8, 9, 64)] == \
        [1, 2, 2, 4, 8, 8, 64]
    assert [_pow2_ceil(n) for n in (1, 2, 3, 7, 8, 9, 64)] == \
        [1, 2, 4, 8, 8, 16, 64]
    # degenerate inputs clamp to 1 instead of crashing or returning 0
    assert _pow2_floor(0) == _pow2_ceil(0) == 1
    assert _pow2_floor(-3) == _pow2_ceil(-3) == 1


# -- poll anchor: absolute cadence ------------------------------------------------


def test_poll_anchor_is_absolute_and_strictly_ahead():
    # anchors at multiples of the cadence, strictly after s
    assert _poll_anchor(0, 8) == 8
    assert _poll_anchor(7, 8) == 8
    assert _poll_anchor(8, 8) == 16     # ON a multiple: the NEXT one
    assert _poll_anchor(9, 8) == 16
    # a non-multiple current step still anchors to the absolute grid — the
    # window must not slide with s (a sliding window never comes due)
    assert _poll_anchor(3, 8) == 8
    assert _poll_anchor(11, 8) == 16
    for s in range(40):
        nxt = _poll_anchor(s, 8)
        assert nxt > s and nxt % 8 == 0


# -- host-rule event step ---------------------------------------------------------


def test_next_event_step_picks_nearest_of_all_sources():
    starts = np.array([0, 2, 0])
    budgets = np.array([8.0, 4.0, 2.0])
    live = [0, 1, 2]
    # sources at s=0: poll anchor 16, budget ends {8, 6, 2}, rung boundary 2
    # for lane 0 (local 0 < 2 <= 8) and lane 2; lane 1's first reachable
    # boundary is 2 at global 4.  Nearest: 2.
    assert _next_event_step(0, 16, starts, budgets, live, (2, 4)) == 2
    # at s=2 lane 2 is done (local == budget): its end is AT s -> returns s
    assert _next_event_step(2, 16, starts, budgets, live, (2, 4)) == 2
    # lane 2 retired: next is lane 0's rung-4 boundary / lane 1's global 4
    assert _next_event_step(2, 16, starts, budgets, [0, 1], (2, 4)) == 4
    # no boundaries: budget ends only
    assert _next_event_step(0, 16, starts, budgets, [0], ()) == 8
    # no live lanes: the poll anchor
    assert _next_event_step(5, 16, starts, budgets, [], (2, 4)) == 16


def test_next_event_step_never_returns_the_past():
    """The chunk-boundary off-by-one: a lane whose budget end or boundary is
    already behind ``s`` (it froze mid-chunk; the loop advanced past it) must
    not drag the next event backwards — the helper clamps to ``s``."""
    starts = np.array([0, 0])
    budgets = np.array([2.0, 8.0])
    # s=3: lane 0 ended at 2 (in the past), lane 1's boundary 4 is ahead
    assert _next_event_step(3, 16, starts, budgets, [0, 1], (2, 4)) == 3
    # once lane 0 is retired the true next event shows through
    assert _next_event_step(3, 16, starts, budgets, [1], (2, 4)) == 4
    # a zero-budget lease: due NOW, at any s — including s=0 (no dispatch)
    assert _next_event_step(0, 16, np.array([0]), np.array([0.0]), [0]) == 0
    for s in range(12):
        got = _next_event_step(s, 16, starts, budgets, [0, 1], (2, 4))
        assert got >= s


def test_next_event_gap_bounded_by_cadence():
    """Between events the engine is blind to divergence: the gap from any s
    to its next event never exceeds the poll cadence."""
    starts = np.array([0, 3])
    budgets = np.array([64.0, 32.0])
    for cadence in (8, 16):
        for s in range(0, 40):
            got = _next_event_step(s, cadence, starts, budgets, [0, 1], (2, 4))
            assert s <= got <= s + cadence


# -- device-rule horizon ----------------------------------------------------------


def test_device_dispatch_horizon_ignores_event_gaps():
    starts = np.array([0, 0, 0])
    budgets = np.array([2.0, 4.0, 8.0])
    live = [0, 1, 2]
    # rung boundaries and individual ends are in-scan events now: the horizon
    # is the LAST live end (8), capped by the poll anchor
    assert _device_dispatch_horizon(0, 16, starts, budgets, live) == 8
    assert _device_dispatch_horizon(0, 4, starts, budgets, live) == 4
    # mid-flight: still the max end, not the short lanes'
    assert _device_dispatch_horizon(3, 16, starts, budgets, live) == 8
    # past every end (all lanes frozen in-scan): clamps to s, never the past
    assert _device_dispatch_horizon(9, 16, starts, budgets, live) == 9
    # zero-budget lease: due now
    assert _device_dispatch_horizon(0, 16, np.array([0]), np.array([0.0]),
                                    [0]) == 0
    # no live lanes: the poll anchor
    assert _device_dispatch_horizon(5, 16, starts, budgets, []) == 16


# -- integration: a zero-budget job completes without a dispatch ------------------


@pytest.mark.parametrize("device_rules", [False, True])
def test_zero_budget_lease_completes_without_training(device_rules):
    """n_iterations=0 is the degenerate lease the clamp protects: its event
    is due the moment it is leased, so it must retire on the spot (0 steps,
    sentinel-free) instead of panicking the dispatch loop — alongside a real
    lane that trains normally."""
    from repro.core.proposer.early_stop import InFlightSuccessiveHalving

    cfgs = [
        {"learning_rate": 1e-3, "stream": 0, "n_iterations": 0},
        {"learning_rate": 2e-3, "stream": 1, "n_iterations": 2},
    ]
    hook = InFlightSuccessiveHalving(eta=2.0, min_iter=2, max_iter=8)
    trial = PopulationTrial("starcoder2-3b", steps=1, batch=2, seq=16, seed=0,
                            population=2, refill_idle_grace_s=0.0,
                            early_stop=hook, chunk_steps=8,
                            device_rules=device_rules)
    feed = QueueFeedScheduler(cfgs)
    trial.run_population([], scheduler=feed)
    assert len(feed.scores) == 2
    assert feed.extras[0]["steps"] == 0
    assert feed.extras[1]["steps"] == 2
    assert feed.extras[1]["diverged"] is False
    assert trial.n_train_steps == 2, \
        "the zero-budget lease must not buy any training dispatch"
