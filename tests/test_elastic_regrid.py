"""Elastic two-level population mesh — the lane-regrid lifecycle op.

The acceptance invariant: a regrid changes *layout*, never *math*.  An
``--elastic-regrid`` ASHA ladder must reproduce the fixed-width run's
per-trial scores — bit-equal within the vmapped family (regrid = pure lane
compaction), <= 1e-6 when the survivors re-layout onto the two-level
``(pop, model)`` mesh through the ``ElasticLanePool`` — while the rung rule
makes the *same decisions* (truncations, reclaims, effective budgets).

On top of the differential cells: unit coverage for ``plan_regrid``
geometry (full-occupancy invariant), the ``regrid`` lane op itself
(gather + pad semantics), the pool's scale-event observability, the
mutual-exclusion guards, and the CI smoke entry.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from harness import LANES, ladder, run_batch_cell, run_elastic_batch_cell, \
    run_elastic_streaming_cell, run_streaming_cell

multi_device = pytest.mark.skipif(
    jax.device_count() < 2, reason="needs a multi-device (virtual CPU) mesh"
)

# (cell name, chunk_steps, pooled two-level placement)
CELLS = [
    ("elastic-perstep", 1, False),
    ("elastic-chunked", 8, False),
    ("elastic-perstep-pooled", 1, True),
    ("elastic-chunked-pooled", 8, True),
]
VMAPPED = [c[0] for c in CELLS if not c[2]]
POOLED = [c[0] for c in CELLS if c[2]]


@pytest.fixture(scope="module")
def cfgs():
    return ladder(6)


@pytest.fixture(scope="module")
def cells(cfgs):
    """Reference fixed-width cells plus every elastic cell, computed once."""
    out = {
        "batch": {"fixed": run_batch_cell(cfgs, chunk=1)},
        "streaming": {"fixed": run_streaming_cell(cfgs, chunk=1)},
    }
    for name, chunk, pooled in CELLS:
        if pooled and jax.device_count() < 2:
            continue
        out["batch"][name] = run_elastic_batch_cell(
            cfgs, chunk=chunk, pool=pooled)
        out["streaming"][name] = run_elastic_streaming_cell(
            cfgs, chunk=chunk, pool=pooled)
    return out


def _cell(cells, protocol, name):
    if name not in cells[protocol]:
        pytest.skip("needs a multi-device (virtual CPU) mesh")
    return cells[protocol][name]


# -- the invariant: regrids change layout, never math ----------------------------


@pytest.mark.parametrize("name", VMAPPED)
@pytest.mark.parametrize("protocol", ["batch", "streaming"])
def test_vmapped_elastic_bit_equal_fixed_width(cells, protocol, name):
    """Within the vmapped family a regrid is a pure lane compaction: scores,
    rule decisions and effective budgets match the fixed-width run to the
    bit — and the workload must actually regrid for this to test anything."""
    ref = cells[protocol]["fixed"]
    got = cells[protocol][name]
    assert got["regrids"] > 0, "workload never regridded; cells are vacuous"
    assert got["scores"] == ref["scores"]
    assert got["n_truncated"] == ref["n_truncated"]
    assert got["n_reclaimed"] == ref["n_reclaimed"]
    if protocol == "streaming":
        assert got["steps"] == ref["steps"]
        assert got["diverged"] == ref["diverged"]


@multi_device
@pytest.mark.parametrize("name", POOLED)
@pytest.mark.parametrize("protocol", ["batch", "streaming"])
def test_pooled_elastic_matches_fixed_width(cells, protocol, name):
    """Two-level placement re-lays survivors across devices; GSPMD may
    re-associate reductions, so scores match to 1e-6 while every rule
    decision stays identical."""
    ref = cells[protocol]["fixed"]
    got = _cell(cells, protocol, name)
    assert got["regrids"] > 0
    np.testing.assert_allclose(got["scores"], ref["scores"],
                               rtol=0, atol=1e-6)
    assert got["n_truncated"] == ref["n_truncated"]
    assert got["n_reclaimed"] == ref["n_reclaimed"]
    if protocol == "streaming":
        assert got["steps"] == ref["steps"]


@multi_device
@pytest.mark.parametrize("protocol", ["batch", "streaming"])
def test_pooled_regrid_keeps_pod_fully_occupied(cells, protocol):
    """After every cut the pod is fully re-leased: lanes x width covers the
    whole device row, and the pool's width grows monotonically as survivors
    thin out (shrink-only workload)."""
    n = jax.device_count()
    got = _cell(cells, protocol, "elastic-perstep-pooled")
    assert got["lane_width_history"], "pooled cell recorded no regrids"
    for lanes, width in got["lane_width_history"]:
        # rows = n/width device rows, each carrying lanes/rows trials:
        # lanes x width is a whole multiple of the pod, never a partial row
        assert n % width == 0 and lanes % (n // width) == 0, (lanes, width, n)
    widths = got["pool_widths"]
    assert widths[0] == 1 and widths == sorted(widths), widths


# -- plan_regrid geometry --------------------------------------------------------


@pytest.mark.parametrize("n,s,want", [
    (8, 8, (8, 1, 8)),   # full house: no widening possible
    (8, 4, (4, 2, 4)),   # halve the lanes, double the width
    (8, 3, (2, 4, 4)),   # 3 survivors pad to 4 lanes of width 4 wait-free
    (8, 5, (2, 4, 6)),   # rows=4 would idle a row ((4-1)*2 >= 5 fails)
    (8, 1, (1, 8, 1)),   # last survivor takes the whole pod
    (6, 4, (2, 3, 4)),   # non-power-of-two pod
    (1, 3, (1, 1, 3)),   # single device: width can never grow
])
def test_plan_regrid_geometry(n, s, want):
    from repro.train.population import plan_regrid

    assert plan_regrid(n, s) == want


@pytest.mark.parametrize("n", [1, 2, 6, 8, 12])
def test_plan_regrid_full_occupancy_invariant(n):
    """For every survivor count: rows*width tiles the pod exactly, every row
    carries at least one live survivor, and lanes >= survivors (pad only)."""
    from repro.train.population import plan_regrid

    for s in range(1, 2 * n + 1):
        rows, width, lanes = plan_regrid(n, s)
        assert rows * width == n
        assert lanes >= s and lanes % rows == 0
        assert (rows - 1) * (lanes // rows) < s, \
            "a device row would carry only dead pad lanes"


# -- the regrid lane op: gather + pad semantics ----------------------------------


def test_regrid_op_gathers_and_pads():
    """``regrid`` compacts survivor lanes in order and pads by repeating the
    first survivor; padded copies are frozen via total_steps=0, not here."""
    from repro.configs.base import ParallelConfig, TrainConfig
    from repro.configs import get_smoke_config
    from repro.train.population import init_population_state_from_keys, \
        regrid_population_state

    tc = TrainConfig(model=get_smoke_config("starcoder2-3b"),
                     parallel=ParallelConfig(remat="none"), seed=0)
    keys = jax.random.split(jax.random.PRNGKey(0), 4)
    pstate = init_population_state_from_keys(keys, tc)
    out = regrid_population_state(pstate, [2, 0], tc, pad_to=4)

    def check(src, dst):
        src, dst = np.asarray(src), np.asarray(dst)
        np.testing.assert_array_equal(dst[0], src[2])
        np.testing.assert_array_equal(dst[1], src[0])
        np.testing.assert_array_equal(dst[2], src[2])  # pad = survivor 0
        np.testing.assert_array_equal(dst[3], src[2])

    jax.tree.map(check, pstate, out)


def test_regrid_op_is_cached_and_readonly():
    """The op lives in the lane-op cache (one compile per K) and must not
    donate its inputs: the output K' differs from K, so the source buffers
    are never reusable — and the source state must survive the call."""
    from repro.configs.base import ParallelConfig, TrainConfig
    from repro.configs import get_smoke_config
    from repro.train.population import get_compiled_lane_op, \
        init_population_state_from_keys

    tc = TrainConfig(model=get_smoke_config("starcoder2-3b"),
                     parallel=ParallelConfig(remat="none"), seed=0)
    assert get_compiled_lane_op(tc, 4, "regrid") is \
        get_compiled_lane_op(tc, 4, "regrid")
    keys = jax.random.split(jax.random.PRNGKey(0), 4)
    pstate = init_population_state_from_keys(keys, tc)
    before = np.asarray(pstate["last_loss"]).copy()
    get_compiled_lane_op(tc, 4, "regrid")(pstate, jnp.arange(2, dtype=jnp.int32))
    np.testing.assert_array_equal(np.asarray(pstate["last_loss"]), before)


# -- ElasticLanePool: scale events are observable --------------------------------


@multi_device
def test_elastic_pool_emits_scale_events():
    from repro.core.resource.sharded import ElasticLanePool

    n = jax.device_count()
    pool = ElasticLanePool()
    assert pool.width == 1 and len(pool._lease_ids) == n
    assert all(i.endswith("xW1") for i in pool._lease_ids)

    (rows, width, lanes), mesh = pool.regrid(n // 2)
    assert rows * width == n and pool.n_regrids == 1
    assert len(pool._lease_ids) == rows
    assert all(i.endswith(f"xW{width}") for i in pool._lease_ids)
    # old width-1 leases were scaled in, new ones out — both visible in the
    # elastic manager's bookkeeping
    assert pool.manager.n_total() == rows
    assert set(mesh.shape.keys()) == {"pop", "model"}
    assert mesh.shape["pop"] * mesh.shape["model"] == n

    # same survivor count again: a no-op plan emits no new scale events
    pool.regrid(n // 2)
    assert pool.n_regrids == 1 and pool.width_history.count(width) == 1


def test_elastic_pool_rejects_untileable_width():
    from repro.core.resource.sharded import ElasticLanePool

    with pytest.raises(ValueError, match="does not tile"):
        ElasticLanePool(devices=jax.devices(), width=3 * jax.device_count())


# -- mutual-exclusion guards -----------------------------------------------------


def test_elastic_rejects_device_rules():
    from harness import _elastic_trial

    trial = _elastic_trial(1)
    trial.device_rules = True
    with pytest.raises(ValueError, match="device-rules"):
        trial.run_population(ladder(2))


def test_cli_rejects_incompatible_engines():
    from repro.launch.hpo import main

    base = ["--proposer", "asha", "--vectorize", "4", "--inflight-stop",
            "--n-samples", "2", "--steps", "2", "--batch", "2", "--seq", "16"]
    with pytest.raises(SystemExit):
        main(base + ["--elastic-regrid", "--device-rules"])
    with pytest.raises(SystemExit):
        main(["--proposer", "pbt", "--vectorize", "4", "--pbt-streaming",
              "--elastic-regrid", "--n-samples", "2", "--steps", "2"])
    with pytest.raises(SystemExit):
        main(["--proposer", "asha", "--elastic-regrid", "--n-samples", "2"])


# -- CI smoke entry --------------------------------------------------------------


def test_elastic_smoke_cli(capsys):
    """The CI smoke entry (`REPRO_ELASTIC_SMOKE=1`) runs the heavier CLI with
    --elastic-regrid; locally a lighter variant stays always-on.  The ladder
    must regrid at least once and stamp the engine suffix."""
    import json
    import os

    from repro.launch.hpo import main

    heavy = os.environ.get("REPRO_ELASTIC_SMOKE") == "1"
    argv = ["--proposer", "asha", "--vectorize", "8" if heavy else "4",
            "--inflight-stop", "--elastic-regrid",
            "--n-samples", "6" if heavy else "4",
            "--steps", "2", "--batch", "2", "--seq", "16"]
    if heavy:
        argv += ["--shard-population"]
    assert main(argv) == 0
    out = json.loads(capsys.readouterr().out)
    assert out["engine"].endswith("+elastic"), out["engine"]
    assert out["regrids"] > 0, out
    assert out["lane_width_history"], out
